"""Paged-serving frontier sweep (DESIGN.md §9): page size x kv-dtype x
slot count -> (cache bytes, useful tok/s, concurrency, prefix hits).

This is the measurement behind the acceptance claim: at a fixed page-pool
byte budget (the dense baseline's ``max_slots x max_len`` cache), smaller
pages waste less tail space and int8 pages halve bytes/token, so more
requests fit in flight. Each sweep point runs the same shared-prefix
workload through the continuous engine and reports the memory/throughput
frontier as CSV (and optionally JSON).

Usage:
  PYTHONPATH=src:. python benchmarks/paging_bench.py --quick
  ... --json experiments/paging_frontier.json
  ... --page-sizes 4,8,16 --slots 4,8,16 --kv-dtypes bf16,int8
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence

import jax

from repro.configs import get_config
from repro.launch.serve import run_continuous
from repro.serving import ContinuousScheduler


def sweep_point(cfg, params, prompts, gens, *, max_len: int, slots: int,
                page_size: int, kv_dtype: Optional[str],
                n_pages: int) -> dict:
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len,
                              cache="paged", page_size=page_size,
                              n_pages=n_pages, kv_dtype=kv_dtype)
    eng.load(params)
    _, m = run_continuous(eng, prompts, gens)
    return {
        "page_size": page_size,
        "kv_dtype": kv_dtype or "bf16",
        "slots": slots,
        "pages": m["cache"]["pages_total"],
        "cache_bytes": m["cache"]["nbytes"],
        "tok_per_s": m["tok_per_s"],
        "wall_s": m["wall_s"],
        "peak_live": m["concurrency"]["peak"],
        "mean_live": m["concurrency"]["mean"],
        "prefix_hit_rate": m["cache"]["prefix"]["hit_rate"],
        "preemptions": m["cache"]["preemptions"],
        "deferrals": m["cache"]["deferrals"],
        "drained": m["drained"],
    }


def main(argv: Optional[Sequence[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--page-sizes", default="")
    ap.add_argument("--slots", default="")
    ap.add_argument("--kv-dtypes", default="bf16,int8")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="also write the frontier rows as JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    quick = args.quick
    requests = args.requests or (12 if quick else 32)
    prefix_len, distinct_len = (8, 8) if quick else (16, 16)
    gen_lens = (4, 4, 4, 24) if quick else (8, 8, 8, 48)
    max_len = prefix_len + distinct_len + max(gen_lens) + 1
    page_sizes = [int(p) for p in args.page_sizes.split(",") if p] or \
        ([4, 8] if quick else [4, 8, 16])
    slot_counts = [int(s) for s in args.slots.split(",") if s] or \
        ([4, 8] if quick else [4, 8, 16])
    kv_dtypes = [None if d in ("bf16", "") else d
                 for d in args.kv_dtypes.split(",")]

    from benchmarks.serving_bench import _prefixed_workload
    prompts, gens = _prefixed_workload(cfg, requests, prefix_len,
                                       distinct_len, gen_lens,
                                       seed=args.seed)
    # one shared byte budget for every point: the dense baseline's pool
    budget_slots = min(slot_counts)
    from repro.models import LM
    params = LM(cfg).init(jax.random.PRNGKey(args.seed))

    rows: List[dict] = []
    print("page_size,kv_dtype,slots,pages,cache_bytes,tok_per_s,"
          "peak_live,mean_live,prefix_hit_rate,preemptions,deferrals")
    for ps in page_sizes:
        n_pages = budget_slots * max_len // ps
        for dt in kv_dtypes:
            for slots in slot_counts:
                row = sweep_point(cfg, params, prompts, gens,
                                  max_len=max_len, slots=slots,
                                  page_size=ps, kv_dtype=dt,
                                  n_pages=n_pages)
                rows.append(row)
                print(",".join(str(row[k]) for k in (
                    "page_size", "kv_dtype", "slots", "pages",
                    "cache_bytes", "tok_per_s", "peak_live", "mean_live",
                    "prefix_hit_rate", "preemptions", "deferrals")))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"version": 1, "quick": quick, "rows": rows}, f,
                      indent=1)
        print(f"wrote {len(rows)} frontier rows to {args.json}")
    return rows


if __name__ == "__main__":
    main()
