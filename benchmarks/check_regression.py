#!/usr/bin/env python
"""CI perf-regression gate: compare a ``benchmarks/run.py --json`` output
against the checked-in baseline and fail (exit 1) on regression.

Gated (default tolerance 25% for each):
  * **coverage** — every baseline entry must still be emitted;
  * **aggregate wall time** — the sum of all timed entries must not exceed
    baseline * (1 + --tolerance);
  * **structural ratios** — entries carrying a ``ratio=`` derived field
    (e.g. the continuous-vs-static serving speedup) must not fall below
    baseline_ratio * (1 - --ratio-tolerance), and a >1 baseline speedup
    must stay strictly >1. Ratios are machine-independent, so
    --ratio-tolerance stays tight even when --tolerance is widened for
    slow/noisy CI runners.

Per-entry wall times are *reported* but not individually gated: on shared
CPU runners, individual micro-benchmark timings swing 2-4x between
back-to-back runs on the same machine while the aggregate stays within a
few percent — gating them one by one would make every CI run a coin flip.
Refresh the baseline with:
    python benchmarks/run.py --quick --json benchmarks/baseline_quick.json

Usage:  python benchmarks/check_regression.py BENCH_ci.json \
            [--baseline benchmarks/baseline_quick.json] [--tolerance 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys


def _load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    assert "entries" in data, f"{path}: not a benchmark JSON"
    return data["entries"]


def _ratio_of(derived: str):
    m = re.search(r"(?:^|,)ratio=([0-9.eE+-]+)", derived or "")
    return float(m.group(1)) if m else None


def main(argv=None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="JSON from benchmarks/run.py --json")
    ap.add_argument("--baseline",
                    default=os.path.join(here, "baseline_quick.json"))
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed aggregate wall-time regression "
                         "(0.25 = 25%%)")
    ap.add_argument("--ratio-tolerance", type=float, default=0.25,
                    help="allowed drop in structural ratio= entries")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="report-only noise floor for per-entry listing")
    ap.add_argument("--prefix", default=None,
                    help="gate only baseline entries whose name starts "
                         "with this prefix (coverage, aggregate, and "
                         "ratios restricted to the subset) — used by CI "
                         "legs that run a single bench module against "
                         "the shared baseline")
    args = ap.parse_args(argv)

    base = _load(args.baseline)
    cur = _load(args.current)
    if args.prefix is not None:
        base = {n: e for n, e in base.items()
                if n.startswith(args.prefix)}
        assert base, f"no baseline entries match --prefix {args.prefix}"
    failures = []

    # coverage
    missing = sorted(set(base) - set(cur))
    for name in missing:
        failures.append(f"MISSING  {name}: present in baseline, absent "
                        "from current run")

    # aggregate wall time
    b_total = sum(e["us_per_call"] for e in base.values())
    c_total = sum(cur[n]["us_per_call"] for n in base if n in cur)
    limit = b_total * (1.0 + args.tolerance)
    print(f"aggregate timed total: {c_total / 1e6:.2f}s "
          f"(baseline {b_total / 1e6:.2f}s, limit {limit / 1e6:.2f}s)")
    if c_total > limit:
        failures.append(f"SLOWER   aggregate: {c_total / 1e6:.2f}s vs "
                        f"baseline {b_total / 1e6:.2f}s "
                        f"(limit {limit / 1e6:.2f}s)")

    # structural ratios + per-entry report
    n_ratios = 0
    for name, b in sorted(base.items()):
        c = cur.get(name)
        if c is None:
            continue
        b_us, c_us = b["us_per_call"], c["us_per_call"]
        if b_us >= args.min_us:
            rel = c_us / b_us if b_us else float("inf")
            print(f"  info {name}: {c_us:.0f}us (baseline {b_us:.0f}us, "
                  f"x{rel:.2f})")
        b_ratio, c_ratio = _ratio_of(b["derived"]), _ratio_of(c["derived"])
        if b_ratio is not None and c_ratio is not None:
            n_ratios += 1
            floor = b_ratio * (1.0 - args.ratio_tolerance)
            bad = c_ratio < floor or (b_ratio > 1.0 and c_ratio <= 1.0)
            if bad:
                failures.append(
                    f"RATIO    {name}: {c_ratio:.2f} vs baseline "
                    f"{b_ratio:.2f} (floor {floor:.2f})")
            print(f"{'FAIL' if bad else 'ok':5s} {name}: ratio "
                  f"{c_ratio:.2f} (baseline {b_ratio:.2f})")

    print(f"\ngated: coverage ({len(base)} entries), aggregate time, "
          f"{n_ratios} structural ratio(s)")
    if failures:
        print("\nPERF REGRESSION:", file=sys.stderr)
        for f_ in failures:
            print("  " + f_, file=sys.stderr)
        return 1
    print("no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
