"""Speculative-serving frontier sweep (DESIGN.md §10): draft depth k x
draft sparsity x slot count -> (useful tok/s, acceptance rate, mean
accepted length, verify rounds).

The trade this measures: a deeper window (larger k) amortizes more target
decode steps per verify GEMM but wastes more draft work when acceptance is
low, and a sparser re-ternarized draft is cheaper per proposal but agrees
with the target less often. Every sweep point runs the same mixed-budget
workload through the continuous engine with a ``resparsify`` draft (packed
``TernaryWeight`` params re-ternarized at the sweep sparsity) and is
token-exact vs the sequential baseline by construction — the frontier is
pure throughput/acceptance, never quality. Sequential (spec=off) baselines
per slot count anchor the speedup column.

Usage:
  PYTHONPATH=src:. python benchmarks/spec_bench.py --quick
  ... --json experiments/spec_frontier.json
  ... --ks 1,2,4 --sparsities 0.125,0.25,0.5 --slots 2,4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import build_workload, run_continuous
from repro.serving import ContinuousScheduler
from repro.spec import SpecConfig


def _packed_setup(seed: int = 0, num_layers: int = 2):
    """Reduced ternary-paper config with every projection packed into
    ``TernaryWeight`` containers (resparsify drafts re-pack from these)."""
    from repro.models import LM, layers as L
    cfg = get_config("ternary-paper", reduced=True, num_layers=num_layers,
                     ternary_min_dim=64)
    params = LM(cfg).init(jax.random.PRNGKey(seed))
    packed = L.pack_params(params, cfg)
    cfg = dataclasses.replace(cfg, quantization="ternary_packed")
    return cfg, packed


def sweep_point(cfg, params, prompts, gens, *, max_len: int, slots: int,
                spec: Optional[SpecConfig], base_tok_s: Optional[float],
                ) -> dict:
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len,
                              spec=spec)
    eng.load(params)
    run_continuous(eng, prompts, gens)           # compile warmup
    outs, m = run_continuous(eng, prompts, gens)
    s = m["spec"] or {}
    return {
        "k": spec.k if spec else 0,
        "draft_sparsity": spec.draft_sparsity if spec else None,
        "slots": slots,
        "tok_per_s": m["tok_per_s"],
        "wall_s": m["wall_s"],
        "speedup": (round(m["tok_per_s"] / base_tok_s, 3)
                    if base_tok_s else None),
        "acceptance_rate": s.get("acceptance_rate"),
        "mean_accepted_len": s.get("mean_accepted_len"),
        "rounds": s.get("rounds"),
        "decode_steps": m["decode_steps"],
        "drained": m["drained"],
        "outs": outs,
    }


def main(argv: Optional[Sequence[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ks", default="")
    ap.add_argument("--sparsities", default="")
    ap.add_argument("--slots", default="")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="also write the frontier rows as JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    quick = args.quick
    requests = args.requests or (8 if quick else 24)
    prompt_len = 12 if quick else 24
    gen_lens = (4, 16) if quick else (8, 48)
    ks = [int(k) for k in args.ks.split(",") if k] or \
        ([2, 4] if quick else [1, 2, 4])
    sparsities = [float(s) for s in args.sparsities.split(",") if s] or \
        ([0.25, 1.0] if quick else [0.125, 0.25, 0.5, 1.0])
    slot_counts = [int(s) for s in args.slots.split(",") if s] or \
        ([2] if quick else [2, 4])
    max_len = prompt_len + max(gen_lens) + 1 + max(ks)

    cfg, params = _packed_setup(args.seed)
    prompts, gens, _ = build_workload(cfg, requests, prompt_len, gen_lens,
                                      seed=args.seed)

    rows: List[dict] = []
    print("k,draft_sparsity,slots,tok_per_s,speedup,acceptance_rate,"
          "mean_accepted_len,decode_steps")
    for slots in slot_counts:
        base = sweep_point(cfg, params, prompts, gens, max_len=max_len,
                           slots=slots, spec=None, base_tok_s=None)
        base_outs, base_tok_s = base.pop("outs"), base["tok_per_s"]
        rows.append(base)
        print(f"0,,{slots},{base['tok_per_s']},1.0,,,"
              f"{base['decode_steps']}")
        for k in ks:
            for sp in sparsities:
                row = sweep_point(
                    cfg, params, prompts, gens, max_len=max_len,
                    slots=slots, base_tok_s=base_tok_s,
                    spec=SpecConfig(draft="resparsify", k=k,
                                    draft_sparsity=sp))
                outs = row.pop("outs")
                exact = all(len(a) == len(b) and (np.asarray(a)
                                                  == np.asarray(b)).all()
                            for a, b in zip(base_outs, outs))
                assert exact, (
                    f"spec outputs diverged at k={k} s={sp} slots={slots}")
                rows.append(row)
                print(",".join(str(row[c]) for c in (
                    "k", "draft_sparsity", "slots", "tok_per_s", "speedup",
                    "acceptance_rate", "mean_accepted_len",
                    "decode_steps")))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"version": 1, "quick": quick, "rows": rows}, f,
                      indent=1)
        print(f"wrote {len(rows)} frontier rows to {args.json}")
    return rows


if __name__ == "__main__":
    main()
