"""Paper-faithful benchmarks — one function per paper figure/table.

The paper benchmarks C++ kernels on Apple M1; this reproduction benchmarks
the JAX ports of the same *algorithms* (BaseTCSC, BlockedTCSC,
InterleavedTCSC, and the packed dense-decode path that is the TPU-native
kernel's algorithm) on this container's CPU via XLA. Absolute flops/cycle
differ from the paper's hardware; the *claims* under test are the paper's
qualitative results:

  fig6: variant ranking over K at 50% sparsity (blocked+interleaved best,
        Base worst at large K);
  fig8: performance is flat in N;
  fig9: best-variant performance rises with sparsity (density) and is
        stable across K >= 4096;
  fig10: operational intensity (paper cost / bytes of format+X+Y+b) grows
        with s and K — the workload is memory-bound;
  fig11: the dense-decode (vectorized/MXU analog) path vs scalar-style
        gather variants, with fused PReLU.

Perf metric: the paper's useful-flops cost model C = M*N*(1+sK) divided by
wall time (flops/s), i.e. *useful* throughput — same normalization as the
paper's flops/cycle.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import paper_cost, record, time_fn
from repro.core import formats
from repro.kernels import ref

M_DEF, N_DEF = 32, 512
SPARSITIES = (0.5, 0.25, 0.125, 0.0625)
K_SWEEP = (1024, 2048, 4096, 8192, 16384)


def _inputs(m, k, n, s, seed=0):
    rng = np.random.default_rng(seed)
    w = formats.random_ternary(rng, k, n, s)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    return x, w, bias


def _variants(w, k, block=4096):
    """name -> jitted fn(x, bias)."""
    tcsc = formats.TCSC.from_dense(w)
    blocked = formats.BlockedTCSC.from_dense(w, min(k, block))
    inter = formats.InterleavedTCSC.from_dense(w, 4)
    packed = jnp.asarray(formats.pack_2bit(w))
    dense_t = jnp.asarray(w)

    return {
        "BaseTCSC": jax.jit(lambda x, b: ref.tcsc_matmul(x, tcsc, bias=b)),
        "BlockedTCSC": jax.jit(
            lambda x, b: ref.tcsc_matmul_blocked(x, blocked, bias=b)),
        "InterleavedTCSC": jax.jit(
            lambda x, b: ref.tcsc_matmul_interleaved(x, inter, bias=b)),
        "DenseDecode2bit": jax.jit(
            lambda x, b: ref.packed2bit_matmul(x, packed, k, bias=b)),
        "DenseTernary": jax.jit(
            lambda x, b: ref.ternary_matmul_dense(x, dense_t, bias=b)),
    }


def fig6(quick: bool = False):
    """Variant performance over K at 50% sparsity (paper Fig 6)."""
    s = 0.5
    ks = K_SWEEP[:3] if quick else K_SWEEP
    for k in ks:
        x, w, bias = _inputs(M_DEF, k, N_DEF, s)
        for name, fn in _variants(w, k).items():
            t = time_fn(fn, x, bias)
            gflops = paper_cost(M_DEF, k, N_DEF, s) / t / 1e9
            record(f"fig6/{name}/K={k}", t, f"useful_gflops={gflops:.2f}")


def fig8(quick: bool = False):
    """Performance flat in N at fixed K=8192 (paper Fig 8)."""
    k, s = 8192, 0.25
    ns = (256, 512) if quick else (256, 512, 1024, 2048)
    for n in ns:
        x, w, bias = _inputs(8, k, n, s)
        blocked = formats.BlockedTCSC.from_dense(w, 4096)
        fn = jax.jit(lambda x, b, bl=blocked: ref.tcsc_matmul_blocked(
            x, bl, bias=b))
        t = time_fn(fn, x, bias)
        gflops = paper_cost(8, k, n, s) / t / 1e9
        record(f"fig8/BlockedTCSC/N={n}", t, f"useful_gflops={gflops:.2f}")


def fig9(quick: bool = False):
    """Best variant over K x sparsity (paper Fig 9); block = min(K, 4096)."""
    ks = (2048, 8192) if quick else K_SWEEP
    for s in SPARSITIES:
        for k in ks:
            x, w, bias = _inputs(M_DEF, k, N_DEF, s)
            blocked = formats.BlockedTCSC.from_dense(w, min(k, 4096))
            fn = jax.jit(lambda x, b, bl=blocked: ref.tcsc_matmul_blocked(
                x, bl, bias=b))
            t = time_fn(fn, x, bias)
            gflops = paper_cost(M_DEF, k, N_DEF, s) / t / 1e9
            record(f"fig9/BlockedTCSC/K={k}/s={s}", t,
                   f"useful_gflops={gflops:.2f}")


def fig10(quick: bool = False):
    """Operational intensity heatmap (paper Fig 10) — analytic, exact.
    I = C / (bytes of TCSC format + X + Y + b)."""
    ks = K_SWEEP[:3] if quick else K_SWEEP
    for s in SPARSITIES:
        for k in ks:
            _, w, _ = _inputs(4, k, N_DEF, s)
            tcsc = formats.TCSC.from_dense(w)
            m = M_DEF
            data = tcsc.nbytes() + m * k * 4 + m * N_DEF * 4 + N_DEF * 4
            intensity = paper_cost(m, k, N_DEF, s) / data
            record(f"fig10/intensity/K={k}/s={s}", 0.0,
                   f"flops_per_byte={intensity:.4f}")


def fig11(quick: bool = False):
    """Vectorized-path comparison at 25% sparsity with fused PReLU (paper
    Fig 11): dense-decode (the MXU-feeding algorithm used by the Pallas
    kernel) vs the scalar-style gather variants."""
    s = 0.25
    ks = (512, 2048) if quick else (512, 1024, 2048, 4096, 8192)
    m = n = 256
    for k in ks:
        x, w, bias = _inputs(m, k, n, s)
        packed = jnp.asarray(formats.pack_2bit(w))
        tcsc = formats.TCSC.from_dense(w)
        blocked = formats.BlockedTCSC.from_dense(w, min(k, 4096))
        fns = {
            "Base+PReLU": jax.jit(lambda x, b: ref.tcsc_matmul(
                x, tcsc, bias=b, prelu_alpha=0.25)),
            "Blocked+PReLU": jax.jit(lambda x, b: ref.tcsc_matmul_blocked(
                x, blocked, bias=b, prelu_alpha=0.25)),
            "DenseDecode2bit+PReLU": jax.jit(lambda x, b: ref.packed2bit_matmul(
                x, packed, k, bias=b, prelu_alpha=0.25)),
        }
        for name, fn in fns.items():
            t = time_fn(fn, x, bias)
            gflops = paper_cost(m, k, n, s) / t / 1e9
            record(f"fig11/{name}/K={k}", t, f"useful_gflops={gflops:.2f}")


ALL = [fig6, fig8, fig9, fig10, fig11]
