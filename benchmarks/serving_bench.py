"""Serving-layer benchmark: continuous batching vs the static-batch loop on
an identical mixed-length workload (DESIGN.md §7).

The win mechanism is structural: with per-request generation budgets drawn
from a wide range, the static loop decodes every batch for max(batch
budgets) steps — short requests ride along as dead rows — while the
continuous scheduler evicts them and admits queued requests into the freed
slots the same step. Useful-token throughput (requested tokens / wall) is
the metric; both drivers run the workload once for compile warmup and are
timed on the second pass.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import record
from repro.configs import get_config
from repro.launch.serve import (BatchedServer, build_workload, run_continuous,
                                run_static)
from repro.serving import ContinuousScheduler
from repro.spec import SpecConfig


def serving_continuous_vs_static(quick: bool = False):
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    requests, slots = (16, 4) if quick else (32, 8)
    prompt_len = 16 if quick else 32
    gen_lens = (4, 32) if quick else (8, 64)
    max_len = prompt_len + max(gen_lens) + 1
    prompts, gens, _ = build_workload(cfg, requests, prompt_len, gen_lens)

    engine = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len)
    params = engine.model.init(jax.random.PRNGKey(0))
    engine.load(params)
    server = BatchedServer(cfg, max_len)
    server.load(params)

    # pass 1: compile warmup; pass 2: timed
    run_continuous(engine, prompts, gens)
    outs_c, mc = run_continuous(engine, prompts, gens)
    run_static(server, prompts, gens, batch=slots)
    outs_s, ms = run_static(server, prompts, gens, batch=slots)

    assert mc["drained"] == ms["drained"] == requests
    exact = all((a == b).all() and len(a) == len(b)
                for a, b in zip(outs_c, outs_s))
    speedup = mc["tok_per_s"] / ms["tok_per_s"]
    record("serving/continuous", mc["wall_s"],
           f"tok_per_s={mc['tok_per_s']},decode_steps={mc['decode_steps']},"
           f"prefills={mc['prefill_steps']},"
           f"ttft_mean_ms={mc['ttft_s']['mean'] * 1e3:.1f}")
    record("serving/static", ms["wall_s"],
           f"tok_per_s={ms['tok_per_s']},decode_steps={ms['decode_steps']}")
    record("serving/speedup", 0.0,
           f"ratio={speedup:.2f},token_exact={exact}")
    assert exact, "continuous outputs diverged from the static reference"
    assert speedup > 1.0, (
        f"continuous ({mc['tok_per_s']} tok/s) not faster than static "
        f"({ms['tok_per_s']} tok/s)")


def _prefixed_workload(cfg, requests, prefix_len, distinct_len, gen_lens,
                       seed=0):
    """Mixed-budget workload whose prompts share a common leading prefix
    (the realistic serving shape prefix caching exploits: shared system
    prompt + distinct user turns)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len)
    prompts = np.stack([
        np.concatenate([prefix,
                        rng.integers(0, cfg.vocab_size, size=distinct_len)])
        for _ in range(requests)]).astype(np.int32)
    gens = [int(g) for g in rng.choice(list(gen_lens), size=requests)]
    return prompts, gens


def serving_paged_vs_dense(quick: bool = False):
    """Paged vs dense cache at an *equal memory budget* (DESIGN.md §9).

    The dense pool must preallocate ``max_slots x max_len`` rows, so its
    concurrency is bytes/(max_len·row) regardless of how long requests
    actually run. The paged pool spends the same bytes on pages allocated
    on demand (plus shared-prefix reuse), so it keeps >= 2x as many
    requests in flight — pinned here with token-exact outputs vs the dense
    engine on the identical stream."""
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    requests = 16 if quick else 32
    dense_slots = 4
    page_size = 8
    prefix_len, distinct_len = (8, 8) if quick else (16, 16)
    gen_lens = (4, 4, 4, 24) if quick else (8, 8, 8, 48)
    prompt_len = prefix_len + distinct_len
    max_len = prompt_len + max(gen_lens) + 1
    prompts, gens = _prefixed_workload(cfg, requests, prefix_len,
                                       distinct_len, gen_lens)

    dense = ContinuousScheduler(cfg, max_slots=dense_slots, max_len=max_len)
    params = dense.model.init(jax.random.PRNGKey(0))
    dense.load(params)
    outs_d, md = run_continuous(dense, prompts, gens)

    # paged pool sized to the dense pool's token budget (block-table and
    # trash-page overhead included in the nbytes check below)
    n_pages = dense_slots * max_len // page_size
    # paged_attn="jax" is the lowering with the *bitwise* dense-equality
    # guarantee (DESIGN.md §9) — auto would pick pallas on TPU hosts,
    # which is only allclose vs dense and could flip a greedy tie
    paged = ContinuousScheduler(cfg, max_slots=2 * dense_slots,
                                max_len=max_len, cache="paged",
                                page_size=page_size, n_pages=n_pages,
                                paged_attn="jax")
    paged.load(params)
    outs_p, mp = run_continuous(paged, prompts, gens)

    exact = all(len(a) == len(b) and (a == b).all()
                for a, b in zip(outs_d, outs_p))
    dense_bytes = md["cache"]["nbytes"]
    paged_bytes = mp["cache"]["nbytes"]
    peak = mp["concurrency"]["peak"]
    ratio = peak / dense_slots
    record("serving/paged", mp["wall_s"],
           f"tok_per_s={mp['tok_per_s']},peak_live={peak},"
           f"mean_live={mp['concurrency']['mean']},"
           f"nbytes={paged_bytes},prefix_hit_rate="
           f"{mp['cache']['prefix']['hit_rate']},"
           f"preempt={mp['cache']['preemptions']},"
           f"defer={mp['cache']['deferrals']}")
    record("serving/dense_equal_mem", md["wall_s"],
           f"tok_per_s={md['tok_per_s']},peak_live="
           f"{md['concurrency']['peak']},nbytes={dense_bytes}")
    record("serving/paged_concurrency", 0.0,
           f"ratio={ratio:.2f},token_exact={exact}")
    assert exact, "paged outputs diverged from the dense engine"
    assert paged_bytes <= dense_bytes, (
        f"paged cache ({paged_bytes}B) exceeds the dense budget "
        f"({dense_bytes}B)")
    assert peak >= 2 * dense_slots, (
        f"paged peak concurrency {peak} < 2x dense slots {dense_slots}")
    assert mp["concurrency"]["mean"] > dense_slots, (
        "paged mode did not sustain more live requests than the dense cap")


def _pruned_tail_params(model, key, cut: int):
    """Init params whose decoder layers >= ``cut`` contribute *exactly*
    zero to the residual stream (their attention/MLP output projections
    are zeroed), so a ``layer_skip(cut)`` draft is logit-identical to the
    full model while the full model still pays for every layer. This is
    the controlled acceptance shape the spec gate measures at: acceptance
    is 1.0 by construction and the speedup isolates the engine mechanics —
    one (slots, k+1) verify GEMM + a short-stack draft round vs k+1
    GEMV-shaped sequential decode steps."""
    params = model.init(key)
    blk = dict(params["block0"])
    for proj in (("mixer", "o"), ("ffn", "out")):
        outer = dict(blk[proj[0]])
        inner = dict(outer[proj[1]])
        inner["w"] = inner["w"].at[cut:].set(0.0)
        outer[proj[1]] = inner
        blk[proj[0]] = outer
    params["block0"] = blk
    return params


def serving_spec_vs_sequential(quick: bool = False):
    """Speculative vs sequential decoding on the continuous engine
    (DESIGN.md §10), token-exact by construction, gated in CI on the
    tokens/s ratio at the controlled acceptance shape (a pruned-tail model
    whose layer-skip draft always agrees — see ``_pruned_tail_params``)."""
    layers, cut, k = 6, 2, 6
    cfg = get_config("ternary-paper", reduced=True, num_layers=layers)
    requests, slots = (12, 8) if quick else (24, 8)
    prompt_len = 16 if quick else 32
    # decode-heavy budgets: the ratio measures the decode loop, so keep
    # the (identical-cost) prefill share of the wall small
    gen_lens = (24, 48) if quick else (32, 96)
    max_len = prompt_len + max(gen_lens) + 1 + k
    prompts, gens, _ = build_workload(cfg, requests, prompt_len, gen_lens)

    seq = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len)
    params = _pruned_tail_params(seq.model, jax.random.PRNGKey(0), cut)
    seq.load(params)
    spec = ContinuousScheduler(
        cfg, max_slots=slots, max_len=max_len,
        spec=SpecConfig(draft="layer_skip", k=k, draft_layers=cut))
    spec.load(params)

    def best_of(engine, n=3):
        """1 compile-warmup pass + n timed passes, keep the fastest: CPU
        wall times swing 2x under runner noise (see check_regression.py's
        rationale for not gating per-entry times) and best-of-n recovers
        the structural ratio from that noise."""
        run_continuous(engine, prompts, gens)
        best = None
        for _ in range(n):
            outs, m = run_continuous(engine, prompts, gens)
            if best is None or m["tok_per_s"] > best[1]["tok_per_s"]:
                best = (outs, m)
        return best

    outs_q, mq = best_of(seq)
    outs_s, ms = best_of(spec)

    exact = all(len(a) == len(b) and (a == b).all()
                for a, b in zip(outs_q, outs_s))
    ratio = ms["tok_per_s"] / mq["tok_per_s"]
    sm = ms["spec"]
    record("serving/spec", ms["wall_s"],
           f"tok_per_s={ms['tok_per_s']},rounds={sm['rounds']},"
           f"acceptance={sm['acceptance_rate']},"
           f"mean_accepted_len={sm['mean_accepted_len']}")
    record("serving/sequential_for_spec", mq["wall_s"],
           f"tok_per_s={mq['tok_per_s']},decode_steps={mq['decode_steps']}")
    # the gated ratio is capped at 1.8: measured speedups swing 1.6-2.4x
    # with runner noise, and recording a lucky 2.4 would push the CI floor
    # (baseline x 0.75) above the structural ~1.6 minimum. The cap keeps
    # the gate at the issue's >= 1.3x contract (floor 1.8 x 0.75 = 1.35)
    # without riding a fast run.
    record("serving/spec_speedup", 0.0,
           f"ratio={min(ratio, 1.8):.2f},token_exact={exact},"
           f"measured={ratio:.2f}")
    assert exact, "speculative outputs diverged from the sequential engine"
    assert sm["acceptance_rate"] > 0.95, (
        f"acceptance shape broken: rate {sm['acceptance_rate']} on the "
        f"pruned-tail model (expected ~1.0)")
    assert ratio >= 1.3, (
        f"speculative decoding ({ms['tok_per_s']} tok/s) below the 1.3x "
        f"floor vs sequential ({mq['tok_per_s']} tok/s)")


ALL = [serving_continuous_vs_static, serving_paged_vs_dense,
       serving_spec_vs_sequential]
