"""Serving-layer benchmark: continuous batching vs the static-batch loop on
an identical mixed-length workload (DESIGN.md §7).

The win mechanism is structural: with per-request generation budgets drawn
from a wide range, the static loop decodes every batch for max(batch
budgets) steps — short requests ride along as dead rows — while the
continuous scheduler evicts them and admits queued requests into the freed
slots the same step. Useful-token throughput (requested tokens / wall) is
the metric; both drivers run the workload once for compile warmup and are
timed on the second pass.
"""
from __future__ import annotations

import jax

from benchmarks.common import record
from repro.configs import get_config
from repro.launch.serve import (BatchedServer, build_workload, run_continuous,
                                run_static)
from repro.serving import ContinuousScheduler


def serving_continuous_vs_static(quick: bool = False):
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    requests, slots = (16, 4) if quick else (32, 8)
    prompt_len = 16 if quick else 32
    gen_lens = (4, 32) if quick else (8, 64)
    max_len = prompt_len + max(gen_lens) + 1
    prompts, gens, _ = build_workload(cfg, requests, prompt_len, gen_lens)

    engine = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len)
    params = engine.model.init(jax.random.PRNGKey(0))
    engine.load(params)
    server = BatchedServer(cfg, max_len)
    server.load(params)

    # pass 1: compile warmup; pass 2: timed
    run_continuous(engine, prompts, gens)
    outs_c, mc = run_continuous(engine, prompts, gens)
    run_static(server, prompts, gens, batch=slots)
    outs_s, ms = run_static(server, prompts, gens, batch=slots)

    assert mc["drained"] == ms["drained"] == requests
    exact = all((a == b).all() and len(a) == len(b)
                for a, b in zip(outs_c, outs_s))
    speedup = mc["tok_per_s"] / ms["tok_per_s"]
    record("serving/continuous", mc["wall_s"],
           f"tok_per_s={mc['tok_per_s']},decode_steps={mc['decode_steps']},"
           f"prefills={mc['prefill_steps']},"
           f"ttft_mean_ms={mc['ttft_s']['mean'] * 1e3:.1f}")
    record("serving/static", ms["wall_s"],
           f"tok_per_s={ms['tok_per_s']},decode_steps={ms['decode_steps']}")
    record("serving/speedup", 0.0,
           f"ratio={speedup:.2f},token_exact={exact}")
    assert exact, "continuous outputs diverged from the static reference"
    assert speedup > 1.0, (
        f"continuous ({mc['tok_per_s']} tok/s) not faster than static "
        f"({ms['tok_per_s']} tok/s)")


ALL = [serving_continuous_vs_static]
