"""Benchmark utilities: wall-clock timing of jitted callables + CSV output."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

RESULTS: List[Dict] = []


def time_fn(fn: Callable, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def record(name: str, seconds: float, derived: str = ""):
    us = seconds * 1e6
    RESULTS.append({"name": name, "us_per_call": us, "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def emit_header():
    print("name,us_per_call,derived", flush=True)


def paper_cost(m: int, k: int, n: int, s: float) -> float:
    """The paper's cost model C(M,K,N,s) = M*N*(1 + s*K) fadds (§2)."""
    return m * n * (1.0 + s * k)
