# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for CI-speed runs")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names (fig6,fig8,...)")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_figs, roofline
    from benchmarks.common import emit_header

    emit_header()
    benches = {f.__name__: f for f in paper_figs.ALL + kernel_bench.ALL}
    selected = (args.only.split(",") if args.only else list(benches))
    for name in selected:
        benches[name](quick=args.quick)

    # roofline table from whatever dry-run records exist
    roofline.main()


if __name__ == "__main__":
    main()
