# One function per paper table. Print ``name,us_per_call,derived`` CSV,
# optionally duplicated to JSON (--json) for the CI regression gate
# (benchmarks/check_regression.py).
import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes for CI-speed runs")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names (fig6,fig8,...)")
    ap.add_argument("--json", default="",
                    help="also write results as JSON to this path")
    args = ap.parse_args()

    from benchmarks import (chaos_bench, kernel_bench, latency_bench,
                            obs_bench, paper_figs, roofline, serving_bench,
                            sharding_bench)
    from benchmarks.common import RESULTS, emit_header

    emit_header()
    benches = {f.__name__: f
               for f in paper_figs.ALL + kernel_bench.ALL + serving_bench.ALL
               + chaos_bench.ALL + sharding_bench.ALL + latency_bench.ALL
               + obs_bench.ALL}
    selected = (args.only.split(",") if args.only else list(benches))
    for name in selected:
        benches[name](quick=args.quick)

    # roofline table from whatever dry-run records exist
    roofline.main()

    if args.json:
        entries = {r["name"]: {"us_per_call": r["us_per_call"],
                               "derived": r["derived"]} for r in RESULTS}
        with open(args.json, "w") as f:
            json.dump({"version": 1, "quick": args.quick,
                       "entries": entries}, f, indent=1)
        print(f"wrote {len(entries)} entries to {args.json}")


if __name__ == "__main__":
    main()
