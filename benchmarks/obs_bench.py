"""Observability overhead benchmarks (DESIGN.md §15).

The tracing contract is "low overhead when on, zero cost when off": one
deque append per event, no dict/string work until export, and a
``tracer=None`` engine takes exactly one attribute test per site. This
module measures the contract:

* ``obs_trace_overhead`` — one engine drains a closed-loop workload
  with its ``tracer`` toggled between adjacent decode steps (off, on,
  off, on, ...); each adjacent (off, on) pair of decode steps yields a
  per-pair ratio ``dt_off / dt_on`` — for equal work that *is* the
  traced/untraced tokens/s ratio — and the gated entry is the median
  over a few hundred pairs. Pairing adjacent same-kind steps cancels
  the slow host drift that makes whole-drain comparisons on a shared
  runner swing by +/-5-10%, far more than the ~1-2% effect being
  gated; the median discards scheduler-noise outliers. Capped at 1.0
  so the baseline pins the CI floor at the issue's >= 0.95 contract
  (``check_regression --prefix obs/ --ratio-tolerance 0.05``); the
  uncapped measurement rides along.
* ``obs_trace_export`` — fill a ring past capacity and time
  ``export()`` (the only part of tracing that builds dicts and touches
  the filesystem); report-only, coverage-gated.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import numpy as np

from benchmarks.common import record
from repro.configs import get_config
from repro.obs import Tracer, load_trace, validate_events
from repro.serving import ContinuousScheduler


def _engine(cfg, slots, max_len, params=None, **kw):
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len, **kw)
    if params is None:
        params = eng.model.init(jax.random.PRNGKey(0))
    eng.load(params)
    return eng, params


def _workload(cfg, n, prompt_len, seed=0):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(n, prompt_len)).astype(np.int32)
    gens = [int(g) for g in rng.integers(24, 49, size=n)]
    return prompts, gens


def _drain_paired(eng, tracer, prompts, gens):
    """Drain one closed-loop pass, toggling ``eng.tracer`` between
    adjacent decode steps and timing every step. Returns the
    ``(dt_off, dt_on)`` list of adjacent decode-step pairs; a
    non-decode step (prefill/admit) resets the pending pair so only
    same-kind neighbours are ever compared."""
    import time
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    pairs = []
    pending_off = None
    i = 0
    while eng.has_work():
        on = i % 2 == 1
        eng.tracer = tracer if on else None
        d0 = eng.decode_steps
        t0 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - t0
        if eng.decode_steps == d0:
            pending_off = None
            continue
        i += 1
        if not on:
            pending_off = dt
        elif pending_off is not None:
            pairs.append((pending_off, dt))
            pending_off = None
    return pairs


def obs_trace_overhead(quick: bool = False):
    # num_layers=4 on purpose: the overhead being gated is a fixed
    # per-step cost, so the gate should measure it against a
    # serving-shaped step (~3 ms), not a toy one where host-timer noise
    # is the same order as the step itself
    cfg = get_config("ternary-paper", reduced=True, num_layers=4)
    n = 12 if quick else 24
    drains = 2 if quick else 4
    prompts, gens = _workload(cfg, n, 32)

    tracer = Tracer(capacity=1 << 16)
    eng, _ = _engine(cfg, 8, 96, tracer=tracer)

    # drain 0 compiles both paths (same jitted fns — the toggle only
    # changes host-side emission)
    _drain_paired(eng, tracer, prompts, gens)
    pairs = []
    for _ in range(drains):
        pairs += _drain_paired(eng, tracer, prompts, gens)
    ratios = [dt_off / dt_on for dt_off, dt_on in pairs if dt_on > 0]

    ratio = float(np.median(ratios))
    record("obs/trace_overhead", 0.0,
           f"ratio={min(ratio, 1.0):.3f},measured={ratio:.3f},"
           f"pairs={len(ratios)},events={len(tracer)},"
           f"dropped={tracer.dropped}")
    # loose local sanity floor — the tight 0.95 gate is check_regression's
    # job, against the baseline-pinned ratio
    assert ratio >= 0.5, (
        f"tracing cost {(1 - ratio) * 100:.0f}% of step time "
        f"(median paired ratio {ratio:.3f} over {len(ratios)} pairs)")


def obs_trace_export(quick: bool = False):
    cap = 1 << 14 if quick else 1 << 16
    tracer = Tracer(capacity=cap)
    pid = tracer.new_pid("bench")
    # overfill by 25% to exercise the drop-oldest path too
    for i in range(cap + cap // 4):
        tracer.instant("tick", pid=pid, args={"i": i})
    import time
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.json")
        t0 = time.perf_counter()
        n_events = tracer.export(path)
        dt = time.perf_counter() - t0
        doc = load_trace(path)
        validate_events(doc["traceEvents"])
    assert tracer.dropped == cap // 4, (tracer.dropped, cap // 4)
    record("obs/trace_export", dt,
           f"events={n_events},dropped={tracer.dropped},"
           f"us_per_event={dt / n_events * 1e6:.3f}")


ALL = [obs_trace_overhead, obs_trace_export]


def main(argv=None):
    """Standalone CLI for the CI obs-smoke leg: runs only this module's
    benches and writes the same JSON shape as run.py --json, so
    check_regression.py --prefix obs/ gates it against the shared
    baseline."""
    from benchmarks.common import RESULTS, emit_header
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="also write results as JSON to this path")
    args = ap.parse_args(argv)

    emit_header()
    for bench in ALL:
        bench(quick=args.quick)
    if args.json:
        entries = {r["name"]: {"us_per_call": r["us_per_call"],
                               "derived": r["derived"]} for r in RESULTS}
        with open(args.json, "w") as f:
            json.dump({"version": 1, "quick": args.quick,
                       "entries": entries}, f, indent=1)
        print(f"wrote {len(entries)} entries to {args.json}")


if __name__ == "__main__":
    main()
