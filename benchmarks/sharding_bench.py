"""Mesh-sharded serving benchmarks (DESIGN.md §13).

The bench process keeps the single real CPU device, so the sharded
workloads fork a subprocess with an 8-way forced host mesh (the same
pattern as tests/test_distributed.py) and report back as JSON. Two gated
``ratio=`` entries:

* ``sharding/tp_vs_single`` — a tp=4 engine must produce bitwise the
  single-device engine's tokens; the gated ratio is 1.0-if-exact (host
  "devices" are threads fighting over the same cores, so the measured
  speedup is recorded as an ungated ``tp_speedup=`` field — on real
  accelerators it is the scaling figure of merit).
* ``sharding/router_affinity`` — fraction of repeated-prefix requests the
  dp=2 router lands on the replica already holding their prefix pages
  (>= 0.8 hard-asserted: placement that forgets affinity re-prefills
  shared prefixes from scratch and silently loses the prefix-cache win).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import record

_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
import jax
from repro.configs import get_config
from repro.models import LM
from repro.models.layers import pack_params
from repro.serving.engine import ContinuousScheduler
from repro.distributed import tp as tp_lib
from repro.distributed.router import Router

QUICK = %(quick)s
cfg = get_config("ternary-paper", reduced=True)
cfg = dataclasses.replace(cfg, ternary_min_dim=64)
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))
packed = pack_params(params, cfg)
pcfg = dataclasses.replace(cfg, quantization="ternary_packed")
rng = np.random.default_rng(0)

requests = 4 if QUICK else 8
gen = 6 if QUICK else 12
max_len = 16 + gen + 8

def build(mesh):
    eng = ContinuousScheduler(pcfg, 2, max_len, cache="paged", page_size=4,
                              mesh=mesh)
    eng.load(packed)
    return eng

def serve(eng, prompts, gens):
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    m = eng.run()
    return [[int(t) for t in r.tokens] for r in reqs], m

# --- tp=4 vs single device: token exactness + throughput ---------------
prompts = [rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
           for _ in range(requests)]
gens = [gen] * requests
single = build(None)
serve(single, prompts, gens)                     # compile warmup
ref, m_single = serve(single, prompts, gens)
tp_eng = build(tp_lib.replica_meshes(1, 4)[0])
serve(tp_eng, prompts, gens)                     # compile warmup
got, m_tp = serve(tp_eng, prompts, gens)

# --- dp=2 x tp=4 router: prefix affinity -------------------------------
def make_prompt(prefix, seed):
    tail = np.random.default_rng(seed).integers(
        1, cfg.vocab_size, size=4).astype(np.int32)
    return np.concatenate([prefix, tail])

pa = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
pb = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
router = Router([build(m) for m in tp_lib.replica_meshes(2, 4)])
for p in (make_prompt(pa, 100), make_prompt(pb, 101)):   # warm both
    router.submit(p, gen)
router.run()
hot = 10 if QUICK else 20
for i in range(hot):
    router.submit(make_prompt(pa if i %% 2 == 0 else pb, i), gen)
m_router = router.run()

print(json.dumps({
    "exact": got == ref,
    "single": {"wall_s": m_single["wall_s"],
               "tok_per_s": m_single["tok_per_s"]},
    "tp": {"wall_s": m_tp["wall_s"], "tok_per_s": m_tp["tok_per_s"],
           "mesh": m_tp["mesh"]},
    "router": {"wall_s": m_router["wall_s"],
               "tok_per_s": m_router["tok_per_s"],
               "affinity": m_router["affinity"],
               "spills": m_router["spills"],
               "drained": [r["drained"]
                           for r in m_router["per_replica"]]},
}))
"""


def _run_mesh_subprocess(quick: bool) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUB % {"quick": quick}],
        capture_output=True, text=True, timeout=1800, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def sharded_serving(quick: bool = False):
    res = _run_mesh_subprocess(quick)

    exact = res["exact"]
    speedup = res["tp"]["tok_per_s"] / res["single"]["tok_per_s"]
    record("sharding/tp_serve", res["tp"]["wall_s"],
           f"tok_per_s={res['tp']['tok_per_s']},"
           f"collective_plans={res['tp']['mesh']['collective_plans']}")
    record("sharding/single_for_tp", res["single"]["wall_s"],
           f"tok_per_s={res['single']['tok_per_s']}")
    record("sharding/tp_vs_single", 0.0,
           f"ratio={1.0 if exact else 0.0:.2f},token_exact={exact},"
           f"tp_speedup={speedup:.2f}")
    assert exact, "tp=4 tokens diverged from the single-device engine"

    aff = res["router"]["affinity"]
    rate = aff["rate"] or 0.0
    record("sharding/router_affinity", res["router"]["wall_s"],
           f"ratio={rate:.2f},hits={aff['hits']},"
           f"candidates={aff['candidates']},spills={res['router']['spills']},"
           f"tok_per_s={res['router']['tok_per_s']}")
    assert rate >= 0.8, (
        f"router prefix affinity collapsed: {aff['hits']}/"
        f"{aff['candidates']} repeated-prefix requests routed to the "
        f"replica holding their pages (rate {rate:.2f} < 0.8)")
    assert all(d > 0 for d in res["router"]["drained"]), (
        "a replica sat idle through the routed workload")


ALL = [sharded_serving]
