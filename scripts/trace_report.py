#!/usr/bin/env python
"""Analyse a ``serve.py --trace`` export (DESIGN.md §15): step-time
breakdown, prefill/decode interleave bubbles, the per-request TTFT
attribution waterfall, and measured-vs-modeled kernel utilization.

The input is the Chrome trace-event JSON the engine's ``obs.trace.Tracer``
writes — the same file Perfetto renders visually; this gives the numeric
summary. Sections:

  * **step breakdown** — engine-track complete spans (decode_step,
    chunk_window, prefill, draft, verify) per engine pid: count, total
    seconds, p50/p90/p99 duration.
  * **interleave** — wall-clock span covered by the engine track, the
    fraction busy inside kernel spans vs scheduling bubbles, and how the
    busy time splits between prefill-side (prefill, chunk_window) and
    decode-side (decode_step, draft, verify) work.
  * **TTFT waterfall** — per request: queue wait vs prefill vs (chunked)
    chunk count, worst first — where the first token actually went.
  * **measured vs modeled** — kernel spans carry their plan's modeled
    roofline (``model_time_s``, bytes, flops); compare against measured
    wall time per span name: measured/modeled time ratio and achieved
    fraction of the modeled bandwidth/compute ceiling.

Usage:
  PYTHONPATH=src python scripts/trace_report.py TRACE.json [--json]
      [--top 8]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import percentiles  # noqa: E402
from repro.obs.trace import load_trace, validate_events  # noqa: E402

# engine-track span names by scheduler side; anything else on tid 0 is
# still counted in the by-name breakdown, just not attributed to a side
PREFILL_SIDE = ("prefill", "chunk_window")
DECODE_SIDE = ("decode_step", "draft", "verify")


def _engine_spans(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Complete spans on an engine's scheduler track (tid 0)."""
    return [e for e in events
            if e.get("ph") == "X" and e.get("tid") == 0]


def _busy_us(spans: List[Dict[str, Any]]) -> int:
    """Union length of [ts, ts+dur) intervals — overlapping spans (a
    chunk_window inside the same step as a decode_step) count once."""
    ivs = sorted((e["ts"], e["ts"] + e["dur"]) for e in spans)
    busy, end = 0, None
    for lo, hi in ivs:
        if end is None or lo > end:
            busy += hi - lo
            end = hi
        elif hi > end:
            busy += hi - end
            end = hi
    return busy


def step_breakdown(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    by_name: Dict[str, List[float]] = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e["dur"] / 1e6)
    return {name: dict(percentiles(durs) or {},
                       total_s=round(sum(durs), 6))
            for name, durs in sorted(by_name.items())}


def interleave(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    if not spans:
        return {"span_s": 0.0, "busy_frac": None, "bubble_frac": None,
                "prefill_frac": None, "decode_frac": None}
    t_lo = min(e["ts"] for e in spans)
    t_hi = max(e["ts"] + e["dur"] for e in spans)
    span_us = max(t_hi - t_lo, 1)
    busy = _busy_us(spans)
    pre = _busy_us([e for e in spans if e["name"] in PREFILL_SIDE])
    dec = _busy_us([e for e in spans if e["name"] in DECODE_SIDE])
    return {"span_s": round(span_us / 1e6, 6),
            "busy_frac": round(busy / span_us, 4),
            # scheduling bubbles: wall time on the engine track outside
            # any kernel span — host bookkeeping, queue waits, idle ticks
            "bubble_frac": round(1.0 - busy / span_us, 4),
            "prefill_frac": round(pre / span_us, 4),
            "decode_frac": round(dec / span_us, 4)}


def ttft_waterfall(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    per_rid: Dict[int, Dict[str, Any]] = {}
    for e in events:
        rid = (e.get("args") or {}).get("rid")
        if rid is None:
            continue
        row = per_rid.setdefault(rid, {"rid": rid})
        if e["ph"] == "X" and e["name"] in ("queue_wait", "prefill"):
            row[e["name"] + "_s"] = round(e["dur"] / 1e6, 6)
            if e["name"] == "prefill":
                row["chunks"] = e["args"].get("chunks")
    rows = [r for r in per_rid.values()
            if "queue_wait_s" in r or "prefill_s" in r]
    for r in rows:
        r["ttft_s"] = round(r.get("queue_wait_s", 0.0)
                            + r.get("prefill_s", 0.0), 6)
    rows.sort(key=lambda r: -r["ttft_s"])
    return rows


def measured_vs_modeled(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    by_name: Dict[str, List[Dict[str, Any]]] = {}
    for e in spans:
        args = e.get("args") or {}
        if "model_time_s" in args:
            by_name.setdefault(e["name"], []).append(e)
    for name, evs in sorted(by_name.items()):
        measured = sum(e["dur"] for e in evs) / 1e6
        modeled = sum(e["args"]["model_time_s"] for e in evs)
        flops = sum(e["args"].get("modeled_flops", 0) for e in evs)
        out[name] = {
            "n": len(evs),
            "measured_s": round(measured, 6),
            "modeled_s": round(modeled, 6),
            # >1: slower than the roofline model says it could be (host
            # dispatch, unmodeled memory traffic); the gap IS the finding
            "measured_vs_model": (round(measured / modeled, 3)
                                  if modeled > 0 else None),
            "achieved_flops": (round(flops / measured, 1)
                               if measured > 0 and flops else None),
        }
    return out


def report(path: str) -> Dict[str, Any]:
    doc = load_trace(path)
    events = doc["traceEvents"]
    validate_events(events)
    spans = _engine_spans(events)
    return {
        "file": path,
        "events": len(events),
        "dropped": (doc.get("otherData") or {}).get("dropped_events", 0),
        "step_breakdown": step_breakdown(spans),
        "interleave": interleave(spans),
        "ttft_waterfall": ttft_waterfall(events),
        "measured_vs_modeled": measured_vs_modeled(spans),
    }


def _fmt_pct(v) -> str:
    return "n/a" if v is None else f"{100 * v:5.1f}%"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarise a serve.py --trace export")
    ap.add_argument("trace", help="Chrome trace-event JSON from --trace")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON instead of text")
    ap.add_argument("--top", type=int, default=8,
                    help="TTFT waterfall rows shown in text mode")
    args = ap.parse_args(argv)
    rep = report(args.trace)
    if args.json:
        print(json.dumps(rep, indent=2))
        return 0

    print(f"trace: {rep['file']}  ({rep['events']} events, "
          f"{rep['dropped']} dropped)")
    print("\n== step-time breakdown (engine track) ==")
    for name, s in rep["step_breakdown"].items():
        print(f"  {name:<14} n={s['n']:<5} total={s['total_s']:.4f}s  "
              f"p50={s['p50'] * 1e3:.2f}ms p90={s['p90'] * 1e3:.2f}ms "
              f"p99={s['p99'] * 1e3:.2f}ms")
    il = rep["interleave"]
    print("\n== interleave ==")
    print(f"  span={il['span_s']:.4f}s busy={_fmt_pct(il['busy_frac'])} "
          f"bubbles={_fmt_pct(il['bubble_frac'])} "
          f"(prefill-side={_fmt_pct(il['prefill_frac'])}, "
          f"decode-side={_fmt_pct(il['decode_frac'])})")
    print(f"\n== TTFT waterfall (worst {args.top}) ==")
    for r in rep["ttft_waterfall"][:args.top]:
        chunks = f" chunks={r['chunks']}" if r.get("chunks") else ""
        print(f"  rid={r['rid']:<4} ttft={r['ttft_s'] * 1e3:8.2f}ms  "
              f"queue={r.get('queue_wait_s', 0.0) * 1e3:8.2f}ms  "
              f"prefill={r.get('prefill_s', 0.0) * 1e3:8.2f}ms{chunks}")
    mvm = rep["measured_vs_modeled"]
    if mvm:
        print("\n== measured vs modeled (kernel spans) ==")
        for name, s in mvm.items():
            ratio = s["measured_vs_model"]
            print(f"  {name:<14} n={s['n']:<5} "
                  f"measured={s['measured_s']:.4f}s "
                  f"modeled={s['modeled_s']:.6f}s  "
                  f"x{ratio if ratio is not None else 'n/a'} of model")
    return 0


if __name__ == "__main__":
    sys.exit(main())
