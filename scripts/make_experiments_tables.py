"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json. Usage:
  PYTHONPATH=src python scripts/make_experiments_tables.py > /tmp/tables.md
"""
import glob
import json
import sys

ARCH_ORDER = ["seamless-m4t-large-v2", "mistral-nemo-12b", "command-r-35b",
              "granite-3-8b", "deepseek-coder-33b", "jamba-v0.1-52b",
              "kimi-k2-1t-a32b", "mixtral-8x22b", "mamba2-130m",
              "internvl2-76b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    recs = {}
    for f in glob.glob("experiments/dryrun/*.json"):
        r = json.load(open(f))
        if r.get("mesh") == mesh and not r.get("overrides") \
                and r.get("quant") in ("none", ""):
            recs[(r["arch"], r["shape"])] = r
    return recs


def fmt(x, p=2):
    if x is None:
        return ""
    return f"{x:.{p}e}"


def roofline_table(recs):
    print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
          "| dominant | roofline frac | useful ratio | GB/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                print(f"| {a} | {s} | — | — | — | (missing) | | | |")
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | — | — | — | skipped: "
                      f"{r['reason'][:48]} | | | |")
                continue
            tc, tm, tl = (r["t_compute_s"], r["t_memory_s"],
                          r["t_collective_s"])
            bound = max(tc, tm, tl)
            gb = (r["memory"].get("argument_size_in_bytes", 0)
                  + r["memory"].get("temp_size_in_bytes", 0)) / 2**30
            print(f"| {a} | {s} | {fmt(tc)} | {fmt(tm)} | {fmt(tl)} "
                  f"| {r['dominant']} | {tc / bound:.3f} "
                  f"| {(r.get('useful_flops_ratio') or 0):.2f} | {gb:.1f} |")


def dryrun_table(recs, mesh):
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    er = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"mesh {mesh}: {ok} compiled ok, {sk} documented skips, {er} errors")
    print()
    print("| arch | shape | compile (s) | FLOPs/chip | bytes/chip "
          "| coll. bytes/chip | args+temp GB/chip |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None or r["status"] != "ok":
                continue
            gb = (r["memory"].get("argument_size_in_bytes", 0)
                  + r["memory"].get("temp_size_in_bytes", 0)) / 2**30
            print(f"| {a} | {s} | {r['compile_s']} "
                  f"| {fmt(r['hlo_flops_per_chip'])} "
                  f"| {fmt(r['hlo_bytes_per_chip'])} "
                  f"| {fmt(r['collective_bytes_per_chip'].get('total', 0))} "
                  f"| {gb:.1f} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    for mesh in ("16x16", "2x16x16"):
        recs = load(mesh)
        if not recs:
            continue
        print(f"\n### Dry-run — mesh {mesh}\n")
        dryrun_table(recs, mesh)
        if mesh == "16x16" and which != "dryrun":
            print("\n### Roofline — single pod (16x16, 256 chips)\n")
            roofline_table(recs)
