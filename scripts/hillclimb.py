import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""§Perf hillclimb driver: run one cell with config overrides, print the
three roofline terms + per-opcode byte/flop breakdown (hypothesis fuel), and
append the iteration record to experiments/perf/<tag>.json.

Usage:
  PYTHONPATH=src python scripts/hillclimb.py --arch granite-3-8b \
      --shape decode_32k --tag baseline
  ... --set attn_impl=naive --set logits_chunk=1024 --tag iterN
"""
import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.distributed import sharding as shlib  # noqa: E402
from repro.launch import hlo_cost, steps as steps_lib  # noqa: E402
from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS,  # noqa: E402
                                 model_flops)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import LM, set_mesh  # noqa: E402
from repro.obs import clock as obs_clock  # noqa: E402


def lower_cell(arch, shape_name, overrides, multi_pod=False, mesh=None):
    shape = SHAPES[shape_name]
    cfg = get_config(arch, **overrides)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    model = LM(cfg)
    p_shapes, p_sh = steps_lib.model_shardings(model, cfg, mesh)
    batch = steps_lib.input_specs(cfg, shape)
    batch_sh = shlib.batch_sharding(batch, mesh)
    if shape.kind == "train":
        train_step, opt_init = steps_lib.make_train_step(model, cfg)
        opt_shapes = jax.eval_shape(opt_init, p_shapes)
        opt_sh = shlib.opt_state_shardings(p_sh, opt_shapes, mesh)
        return jax.jit(train_step, in_shardings=(p_sh, opt_sh, batch_sh),
                       donate_argnums=(0, 1)).lower(p_shapes, opt_shapes,
                                                    batch), cfg, mesh
    if shape.kind == "prefill":
        prefill_step = steps_lib.make_prefill_step(model, cfg, shape.seq_len)
        return jax.jit(prefill_step, in_shardings=(p_sh, batch_sh)).lower(
            p_shapes, batch), cfg, mesh
    decode_step = steps_lib.make_decode_step(model, cfg)
    cache_shapes, cache_pspec = steps_lib.cache_specs_shapes(model, cfg, shape)
    if cfg.decode_cache_shard == "auto":
        cache_sh = jax.tree.map(lambda _: None, cache_shapes)
    else:
        cache_sh = shlib.resolve_specs(cache_pspec, cache_shapes, mesh,
                                       fsdp=True)
    return jax.jit(decode_step,
                   in_shardings=(p_sh, cache_sh, batch_sh["tokens"]),
                   donate_argnums=(1,)).lower(
        p_shapes, cache_shapes, batch["tokens"]), cfg, mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--kernel-model", action="store_true",
                    help="cost dequant+dot through the fused Pallas kernel")
    ap.add_argument("--autotune-gemm", action="store_true",
                    help="pre-warm the ternary-GEMM block-shape autotune "
                         "cache for this arch's projection shapes and "
                         "record the picks")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        f = ModelConfig.__dataclass_fields__[k]
        typ = f.type if isinstance(f.type, type) else eval(f.type)  # noqa: S307
        overrides[k] = (v.lower() in ("1", "true")) if typ is bool else typ(v)

    t0 = obs_clock.now()
    lowered, cfg, mesh = lower_cell(args.arch, args.shape, overrides,
                                    args.multi_pod)
    compiled = lowered.compile()
    walked = hlo_cost.analyze(compiled.as_text(),
                              kernel_dequant=args.kernel_model)
    mem = compiled.memory_analysis()
    shape = SHAPES[args.shape]
    mf = model_flops(cfg, shape)
    chips = mesh.devices.size
    t_comp = walked.flops / PEAK_FLOPS
    t_mem = walked.bytes / HBM_BW
    t_coll = walked.total_collective() / ICI_BW
    rec = {
        "arch": args.arch, "shape": args.shape, "tag": args.tag,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": max((("compute", t_comp), ("memory", t_mem),
                         ("collective", t_coll)), key=lambda kv: kv[1])[0],
        "flops_per_chip": walked.flops, "bytes_per_chip": walked.bytes,
        "collective_by_type": walked.collective_bytes,
        "useful_ratio": (mf / chips) / walked.flops if walked.flops else None,
        "hbm_gb": (getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "temp_size_in_bytes", 0)) / 2**30,
        "compile_s": round(obs_clock.now() - t0, 1),
        "top_bytes_by_op": [(k, b, f) for k, b, f in walked.top_bytes(args.top)],
    }
    if args.autotune_gemm:
        from repro.kernels.autotune import get_tuner
        tuner = get_tuner()
        d, ff = cfg.d_model, cfg.d_ff or cfg.d_ff_expert or cfg.d_model * 4
        mm = shape.seq_len if shape.kind != "decode" else max(
            shape.global_batch, 8)
        picks = {}
        for din, dout in {(d, ff), (ff, d), (d, d),
                          (d, cfg.padded_vocab())}:
            c = tuner.lookup(mm, din, dout, sparsity=0.25)
            picks[f"{din}x{dout}"] = c.as_list()
        rec["autotune_gemm"] = picks
        print(" autotuned ternary blocks:", picks)

    os.makedirs("experiments/perf", exist_ok=True)
    rec["kernel_model"] = args.kernel_model
    path = f"experiments/perf/{args.arch}_{args.shape}_{args.tag}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"== {args.arch} {args.shape} [{args.tag}] chips={chips} ==")
    print(f" t_compute={t_comp:.4e}s t_memory={t_mem:.4e}s "
          f"t_collective={t_coll:.4e}s dominant={rec['dominant']}")
    print(f" useful_ratio={rec['useful_ratio']:.3f} hbm={rec['hbm_gb']:.1f}GB "
          f"compile={rec['compile_s']}s")
    print(" top ops by bytes (op, GB, GFLOP):")
    for k, b, fl in rec["top_bytes_by_op"]:
        print(f"   {k:24s} {b / 1e9:12.2f} {fl / 1e9:12.2f}")
    print(" collectives:", {k: f"{v / 1e9:.2f}GB"
                            for k, v in walked.collective_bytes.items()})


if __name__ == "__main__":
    main()
